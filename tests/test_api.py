"""repro.api: DipWeight pytree semantics, backend-registry dispatch parity,
tuning-table resolution, and checkpoint round-trips on odd shapes."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import api
from repro.kernels import ref

KEY = jax.random.PRNGKey(11)

# deliberately not multiples of the 64-wide permutation tile
ODD_M, ODD_K, ODD_N = 23, 100, 130


def _mats(m=ODD_M, k=ODD_K, n=ODD_N, dtype="float32", seed=0):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(m, k)).astype(dtype))
    w = jnp.asarray(r.normal(size=(k, n)).astype(dtype))
    return x, w


# ------------------------------------------------------------- DipWeight ----
def test_dip_weight_roundtrip_and_metadata():
    _, w = _mats()
    dw = api.DipWeight.from_natural(w)
    assert dw.shape == (ODD_K, ODD_N)
    assert dw.storage_shape == (128, 192)  # padded to the 64-tile grid
    np.testing.assert_allclose(np.asarray(dw.to_natural()), np.asarray(w))
    # storage really is permutated (not just padded)
    assert not np.array_equal(
        np.asarray(dw.data[:ODD_K, :ODD_N]), np.asarray(w)
    )


def test_dip_weight_stacked_leading_dims():
    r = np.random.default_rng(1)
    w = jnp.asarray(r.normal(size=(3, 70, 90)).astype(np.float32))
    dw = api.DipWeight.from_natural(w)
    assert dw.storage_shape == (3, 128, 128)
    assert dw.shape == (3, 70, 90)
    np.testing.assert_allclose(np.asarray(dw.to_natural()), np.asarray(w))
    # a sliced stack entry is the per-layer DipWeight scan consumes
    sliced = jax.tree_util.tree_map(lambda t: t[1], dw)
    assert isinstance(sliced, api.DipWeight)
    assert sliced.storage_shape == (128, 128) and sliced.d_out == 90


def test_dip_weight_is_a_pytree_through_jit_and_grad():
    x, w = _mats()
    dw = api.DipWeight.from_natural(w)

    # jit: DipWeight crosses the trace boundary as a pytree node
    @jax.jit
    def f(xx, d):
        return api.matmul(xx, d, backend="xla")

    np.testing.assert_allclose(
        np.asarray(f(x, dw)), np.asarray(x @ w), atol=1e-4, rtol=1e-4
    )

    # grad: the cotangent comes back AS a DipWeight with the same metadata
    g = jax.grad(lambda d: jnp.sum(f(x, d) ** 2))(dw)
    assert isinstance(g, api.DipWeight)
    assert (g.d_in, g.d_out, g.perm_tile) == (dw.d_in, dw.d_out, dw.perm_tile)
    assert g.storage_shape == dw.storage_shape

    # flatten/unflatten identity
    leaves, treedef = jax.tree_util.tree_flatten(dw)
    assert len(leaves) == 1
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(back, api.DipWeight) and back.d_out == dw.d_out


def test_dip_weight_astype_rejects_non_float_targets():
    """A bare int8 cast would truncate storage without scales — the error
    must point at the real quantization path (api.quant.quantize)."""
    _, w = _mats()
    dw = api.DipWeight.from_natural(w)
    for bad in ("int8", "int32", "uint8"):
        with pytest.raises(TypeError, match="quant.quantize"):
            dw.astype(bad)
    # float targets stay a plain storage cast; same-dtype is the identity
    assert dw.astype(jnp.bfloat16).dtype == jnp.bfloat16
    assert dw.astype(jnp.float32) is dw
    # and the pointed-at path actually accepts what astype rejects
    qw = api.quant.quantize(dw, "int8")
    assert qw.dtype == jnp.int8 and qw.shape == dw.shape


# ------------------------------------------------------ registry dispatch ---
@pytest.mark.parametrize("backend", ["xla", "ws", "pallas_dip", "pallas_systolic"])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_matmul_matches_ref_oracle_all_backends(backend, dtype):
    """Acceptance: api.matmul == kernels.ref oracle for every registered
    backend on an odd-shaped case (interpret mode on CPU)."""
    x, w = _mats(dtype="float32")
    x, w = x.astype(dtype), w.astype(dtype)
    dw = api.DipWeight.from_natural(w)
    got = api.matmul(x, dw, backend=backend)
    want = ref.dip_matmul_ref(
        jnp.pad(x, [(0, 0), (0, (-ODD_K) % 64)]), dw.data
    )[..., :ODD_N]
    tol = dict(atol=1e-3, rtol=1e-3) if dtype == "float32" else dict(atol=0.5, rtol=0.05)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol
    )


@pytest.mark.parametrize("backend", ["xla", "ws", "pallas_dip", "pallas_systolic"])
def test_matmul_accepts_natural_arrays_on_any_backend(backend):
    x, w = _mats()
    got = api.matmul(x, w, backend=backend)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.ws_matmul_ref(x, w)), atol=1e-3, rtol=1e-3
    )


def test_matmul_batched_leading_dims():
    r = np.random.default_rng(2)
    x = jnp.asarray(r.normal(size=(2, 5, 100)).astype(np.float32))
    w = jnp.asarray(r.normal(size=(100, 70)).astype(np.float32))
    dw = api.DipWeight.from_natural(w)
    got = api.matmul(x, dw, backend="pallas_dip")
    assert got.shape == (2, 5, 70)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(x) @ np.asarray(w), atol=1e-3, rtol=1e-3
    )


def test_unknown_backend_and_duplicate_registration():
    with pytest.raises(KeyError, match="unknown matmul backend"):
        api.matmul(*_mats(), backend="nope")
    with pytest.raises(ValueError, match="already registered"):
        api.register_backend("xla", lambda x, w: x @ w, tiled=False)
    # dip-layout backends go through the tiled padding/VJP shim; a non-tiled
    # one would crash at dispatch, so it must be rejected at registration
    with pytest.raises(ValueError, match="must be tiled"):
        api.register_backend("bad_dip", lambda x, w: x @ w, layout="dip", tiled=False)


def test_dip_dispatch_rejects_padded_width_activations():
    """x wider than the logical d_in must raise, not silently drop features
    into the zero-padding rows (dip and xla paths must agree on validity)."""
    _, w = _mats()  # d_in=100, storage Kp=128
    dw = api.DipWeight.from_natural(w)
    x_padded = jnp.ones((4, 128), jnp.float32)
    for backend in ("pallas_dip", "pallas_systolic", "xla"):
        with pytest.raises(ValueError, match="contraction"):
            api.matmul(x_padded, dw, backend=backend)
    # narrower x on a tile-aligned weight must raise too (no silent
    # zero-imputation of the missing features)
    dw_aligned = api.DipWeight.from_natural(jnp.ones((128, 128), jnp.float32))
    with pytest.raises(ValueError, match="contraction"):
        api.matmul(jnp.ones((4, 100), jnp.float32), dw_aligned, backend="pallas_dip")


def test_register_custom_backend_dispatches():
    name = "test_double_xla"
    if name not in api.list_backends():
        api.register_backend(
            name, lambda x, wn: 2.0 * jnp.matmul(x, wn), layout="natural",
            tiled=False, description="test-only",
        )
    x, w = _mats()
    np.testing.assert_allclose(
        np.asarray(api.matmul(x, w, backend=name)),
        2.0 * (np.asarray(x) @ np.asarray(w)),
        atol=1e-3, rtol=1e-3,
    )


# ------------------------------------------------------------- gradients ----
def test_grad_through_dip_linear_matches_xla_path():
    """Acceptance: jax.grad through a DipWeight linear (Pallas fwd, custom
    VJP bwd) matches the natively-differentiated XLA path to fp32 tol."""
    from repro.models import layers

    x, w = _mats()
    b = jnp.zeros((ODD_N,), jnp.float32)
    dw = api.DipWeight.from_natural(w)

    def loss(backend):
        def f(d, bb):
            out = layers.linear(x, d, bb, backend=backend, compute_dtype=jnp.float32)
            return jnp.mean(out ** 2)
        return f

    for wrt in (0, 1):  # weight grad and bias grad
        g_x = jax.grad(loss("xla"), argnums=wrt)(dw, b)
        g_p = jax.grad(loss("pallas_dip"), argnums=wrt)(dw, b)
        gx, gp = jax.tree_util.tree_leaves(g_x), jax.tree_util.tree_leaves(g_p)
        for a, c in zip(gx, gp):
            np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=1e-5, rtol=1e-5)
    assert isinstance(jax.grad(loss("pallas_dip"))(dw, b), api.DipWeight)


# ----------------------------------------------------------- tuning table ---
def test_tuning_clamp_rounds_bad_entries_to_tile_multiples():
    """A table entry with non-tile-multiple K/N blocks must not poison
    dispatch — clamp_blocks rounds up to the permutation tile."""
    blocks = api.clamp_blocks(api.BlockConfig(96, 96, 96), 1024, 1024, 1024)
    assert blocks == (96, 128, 128)  # M is unconstrained; K/N round up to 64s


def test_tuning_lookup_clamps_to_problem():
    blocks = api.lookup_blocks("pallas_dip", 8, 64, 64, jnp.float32)
    assert blocks == (8, 64, 64)
    blocks = api.lookup_blocks("pallas_dip", 1024, 1024, 1024, jnp.float32)
    assert blocks == (256, 256, 256)
    # bf16 affords deeper K blocks (built-in entry)
    blocks = api.lookup_blocks("pallas_dip", 1024, 1024, 1024, jnp.bfloat16)
    assert blocks.block_k == 512
    # systolic path tiles K/N at the physical array dimension
    blocks = api.lookup_blocks("pallas_systolic", 1024, 1024, 1024, jnp.float32)
    assert (blocks.block_n, blocks.block_k) == (64, 64)


def test_tuning_registration_overrides_and_block_override_is_honoured():
    entry = api.register_tuning(
        (64, 128, 64), backend="pallas_dip", dtype="float32", max_m=16,
    )
    try:
        blocks = api.lookup_blocks("pallas_dip", 16, 256, 256, jnp.float32)
        assert tuple(blocks) == (16, 128, 64)  # m clamped, rest from entry
        x, w = _mats()
        dw = api.DipWeight.from_natural(w)
        got = api.matmul(x, dw, backend="pallas_dip", block_m=64, block_n=64, block_k=64)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(x) @ np.asarray(w), atol=1e-3, rtol=1e-3
        )
    finally:
        from repro.api import tuning as tuning_mod

        tuning_mod._TABLE.remove(entry)


# ------------------------------------------------------------ checkpoints ---
def test_checkpoint_roundtrip_preserves_logical_shape_on_odd_dims(tmp_path):
    """Acceptance: save -> load keeps the logical (d_in, d_out) on dims that
    are not multiples of 64, keyed off the DipWeight type (no hand-threaded
    padding metadata)."""
    from repro.checkpoint import restore_pytree, save_pytree

    r = np.random.default_rng(3)
    nat = jnp.asarray(r.normal(size=(ODD_K, ODD_N)).astype(np.float32))
    tree = {"w": api.DipWeight.from_natural(nat), "b": jnp.zeros((ODD_N,))}
    path = str(tmp_path / "ck")
    save_pytree(path, tree)

    like = jax.eval_shape(lambda: tree)
    got = restore_pytree(path, like)
    assert isinstance(got["w"], api.DipWeight)
    assert got["w"].shape == (ODD_K, ODD_N)
    assert got["w"].storage_shape == (128, 192)
    np.testing.assert_allclose(np.asarray(got["w"].to_natural()), np.asarray(nat))

    # metadata mismatch is detected, not silently mis-cropped
    bad_like = dict(like, w=api.DipWeight(like["w"].data, 128, 192))
    with pytest.raises(ValueError, match="DipWeight metadata mismatch"):
        restore_pytree(path, bad_like)


def test_sharding_walk_matches_param_structure():
    """param_shardings mirrors DipWeight nodes so device_put tree_maps in
    lockstep (single-device mesh here)."""
    from repro.configs.base import ArchConfig
    from repro.distributed.plan import make_plan
    from repro.models import transformer as tf_model

    cfg = ArchConfig(
        name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16, remat="none",
        compute_dtype="float32", matmul_backend="pallas_dip",
    )
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    policy = make_plan(mesh, cfg, "train")
    params = tf_model.init_params(KEY, cfg)
    shardings = policy.param_shardings(tf_model.param_template(cfg))
    placed = jax.tree_util.tree_map(jax.device_put, params, shardings)
    assert isinstance(placed["layers"]["wq"], api.DipWeight)
    # template-derived and params-derived walks agree structurally
    shardings2 = policy.param_shardings(params)
    jax.tree_util.tree_map(lambda a, b: None, shardings, shardings2)
