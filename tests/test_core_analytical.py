"""Validate the analytical models against the paper's own published numbers.

Every assertion here is a claim from the paper (Figs. 5-6, Tables I/II/IV);
this file IS the reproduction scorecard for the paper-native experiments.
"""

import numpy as np
import pytest

from repro.core import analytical, energy, tilesim, workloads


# ----------------------------------------------------------------- Fig. 5 ---
def test_latency_savings_fig5a():
    # paper: saved latency 28% at 3x3 -> 33% at 64x64 (consistent with S=1)
    assert analytical.compare(3, s=1).latency_saving == pytest.approx(2 / 7, abs=1e-9)   # 28.6%
    assert analytical.compare(64, s=1).latency_saving == pytest.approx(0.332, abs=5e-3)  # "33%"
    # with the paper's 2-stage PE the same trend holds (25% -> 32.6%)
    assert analytical.compare(64, s=2).latency_saving == pytest.approx(0.326, abs=5e-3)


def test_throughput_improvement_fig5b():
    # paper: 33.3% at 3x3 -> 49.2% at 64x64 (S=2)
    assert analytical.compare(3, s=2).throughput_improvement == pytest.approx(4 / 3, abs=1e-9)
    assert analytical.compare(64, s=2).throughput_improvement == pytest.approx(1.492, abs=1e-3)


def test_register_savings_fig5c():
    # paper: saved registers reach ~20% at 64x64 (8-bit normalized)
    assert analytical.register_savings_fraction(64) == pytest.approx(0.1975, abs=1e-3)
    assert analytical.ws_fifo_registers(64) == 64 * 63  # eq. (3)


def test_tfpu_fig5d():
    # paper: DiP needs N cycles, WS 2N-1 — "almost half"
    for n in (3, 4, 8, 16, 32, 64):
        assert analytical.dip_tfpu(n) == n
        assert analytical.ws_tfpu(n) == 2 * n - 1
    assert analytical.compare(64).tfpu_improvement == pytest.approx(0.496, abs=1e-3)


def test_peak_throughput_ops_per_cycle():
    # 64x64 @ S=2: DiP 2*64^3/128 = 4096 ops/cycle (peak = 2 ops/PE/cycle)
    assert analytical.dip_throughput(64, 2) == pytest.approx(2 * 64**3 / 128)
    assert analytical.ws_throughput(64, 2) == pytest.approx(2 * 64**3 / 191)


# ---------------------------------------------------------------- Table II --
@pytest.mark.parametrize(
    "n,thr,pwr,area,overall",
    [
        (4, 1.38, 1.16, 1.06, 1.70),
        (8, 1.44, 1.18, 1.08, 1.84),
        (16, 1.47, 1.20, 1.09, 1.93),
        (32, 1.48, 1.25, 1.09, 2.02),
        (64, 1.49, 1.21, 1.07, 1.93),
    ],
)
def test_table_ii_improvements(n, thr, pwr, area, overall):
    imp = energy.table_ii_improvements(n)
    assert imp.throughput == pytest.approx(thr, abs=0.01)
    assert imp.power == pytest.approx(pwr, abs=0.01)
    assert imp.area == pytest.approx(area, abs=0.01)
    # paper rounds each factor before multiplying; allow 0.015x
    assert imp.overall == pytest.approx(overall, abs=0.015)


# ---------------------------------------------------------------- Table IV --
def test_table_iv_peak_performance():
    assert energy.peak_tops(64) == pytest.approx(8.192, abs=1e-3)          # "8.2 TOPS"
    assert energy.energy_efficiency_tops_per_w("dip", 64) == pytest.approx(9.55, abs=0.01)
    assert energy.energy_efficiency_tops_per_w("ws", 64) == pytest.approx(
        8.192 / 1.041, abs=0.01
    )


# ------------------------------------------------------------------ Fig. 6 --
def test_fig6_latency_improvement_endpoints():
    # single 64-tile workload: 1.49x; large (T=32 input tiles): ~1.03x
    small = tilesim.GemmWorkload(64, 64, 64)
    big = tilesim.GemmWorkload(2048, 5120, 5120)
    r_small = (
        tilesim.schedule_gemm(small, "ws").cycles
        / tilesim.schedule_gemm(small, "dip").cycles
    )
    r_big = (
        tilesim.schedule_gemm(big, "ws").cycles
        / tilesim.schedule_gemm(big, "dip").cycles
    )
    assert r_small == pytest.approx(1.492, abs=1e-3)
    assert r_big == pytest.approx(1.030, abs=1e-3)


def test_fig6_energy_improvement_endpoints():
    small = tilesim.GemmWorkload(64, 64, 64)
    big = tilesim.GemmWorkload(2048, 5120, 5120)

    def ratio(wl):
        d = tilesim.schedule_gemm(wl, "dip")
        w = tilesim.schedule_gemm(wl, "ws")
        return energy.workload_energy_j(w.cycles, "ws") / energy.workload_energy_j(
            d.cycles, "dip"
        )

    assert ratio(small) == pytest.approx(1.81, abs=0.01)   # paper: up to 1.81x
    assert ratio(big) == pytest.approx(1.25, abs=0.01)     # paper: down to 1.25x


def test_fig6_improvements_bounded_across_grid():
    """Across the paper's whole workload grid, improvements must stay inside
    the published ranges: latency [1.03, 1.49], energy [1.25, 1.81]."""
    lat, en = [], []
    for _, _, wl in workloads.paper_workload_grid():
        d = tilesim.schedule_gemm(wl, "dip")
        w = tilesim.schedule_gemm(wl, "ws")
        lat.append(w.cycles / d.cycles)
        en.append(
            energy.workload_energy_j(w.cycles, "ws")
            / energy.workload_energy_j(d.cycles, "dip")
        )
    assert min(lat) >= 1.029 and max(lat) <= 1.493
    assert min(en) >= 1.249 and max(en) <= 1.812
    # DiP never loses
    assert all(r > 1 for r in lat)


# ------------------------------------------------------- model consistency --
def test_simulator_agrees_with_analytical_streaming():
    from repro.core import simulator

    rng = np.random.default_rng(0)
    for n in (4, 8):
        for m in (n, 3 * n):
            x = rng.integers(-5, 5, (m, n))
            w = rng.integers(-5, 5, (n, n))
            assert simulator.simulate_dip(x, w).latency == analytical.dip_streaming_latency(n, m)
            assert simulator.simulate_ws(x, w).latency == analytical.ws_streaming_latency(n, m)


def test_tilesim_event_matches_closed_form():
    for wl in (tilesim.GemmWorkload(64, 64, 64), tilesim.GemmWorkload(640, 512, 384)):
        for arch in ("dip", "ws"):
            ev = tilesim.simulate_gemm_event(wl, arch)
            cf = tilesim.schedule_gemm(wl, arch, include_weight_load=True).cycles
            assert ev == cf
            db = tilesim.simulate_gemm_event(wl, arch, double_buffered=True)
            assert db <= cf


def test_hardware_interpolation_hits_calibration_points():
    for arch in ("ws", "dip"):
        for n, hp in energy.TABLE_I[arch].items():
            got = energy.hardware_point(arch, n)
            assert got.area_um2 == hp.area_um2 and got.power_mw == hp.power_mw
    # interpolated point is monotone between neighbours
    p24 = energy.hardware_point("dip", 24)
    assert energy.TABLE_I["dip"][16].area_um2 < p24.area_um2 < energy.TABLE_I["dip"][32].area_um2
