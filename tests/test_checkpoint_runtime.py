"""Fault tolerance: checkpoint atomicity/async/elastic restore, failure
injection + bit-exact resume, straggler signal."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_pytree, save_pytree
from repro.configs.base import ArchConfig
from repro.runtime import Trainer, TrainerConfig


def _tree():
    return {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.int32), "c": jnp.zeros((), jnp.float32)},
    }


def test_roundtrip(tmp_path):
    path = str(tmp_path / "ck")
    tree = _tree()
    save_pytree(path, tree, meta={"step": 5})
    got = restore_pytree(path, jax.eval_shape(lambda: tree))
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        tree, got,
    )


def test_structure_mismatch_rejected(tmp_path):
    path = str(tmp_path / "ck")
    save_pytree(path, _tree())
    bad = {"a": jnp.zeros((2, 3)), "nested": {"WRONG": jnp.zeros(4)}}
    with pytest.raises(ValueError, match="mismatch"):
        restore_pytree(path, bad)


def test_manager_atomicity_orphan_gc(tmp_path):
    d = str(tmp_path)
    # simulate a crash mid-write: orphan tmp dir
    os.makedirs(os.path.join(d, "step_00000007.tmp-dead"), exist_ok=True)
    mgr = CheckpointManager(d, keep=2)
    assert mgr.latest_step() is None          # orphan is not a valid step
    assert not any(".tmp-" in n for n in os.listdir(d))  # gc'd

    mgr.save(1, _tree())
    mgr.save(2, _tree())
    mgr.save(3, _tree())
    assert mgr.steps() == [2, 3]              # retention keep=2
    got, meta = mgr.restore(jax.eval_shape(_tree))
    assert meta["step"] == 3


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(10, _tree(), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 10


def test_elastic_restore_with_shardings(tmp_path):
    """Restore onto an explicit (1-device) mesh placement — the same code
    path that re-meshes a 256-chip checkpoint onto 512 chips."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    path = str(tmp_path / "ck")
    tree = _tree()
    save_pytree(path, tree)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    shardings = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), tree
    )
    got = restore_pytree(path, jax.eval_shape(lambda: tree), shardings=shardings)
    for leaf in jax.tree_util.tree_leaves(got):
        assert isinstance(leaf.sharding, NamedSharding)


# ------------------------------------------------------------ failure drill --
def _tiny_cfg():
    return ArchConfig(
        name="tiny", family="dense", n_layers=2, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=64, vocab_size=128, head_dim=16, remat="none",
        compute_dtype="float32",
    )


def test_failure_injection_and_bitexact_resume(tmp_path):
    """Train A: uninterrupted 8 steps.  Train B: killed at step 6, restarted,
    finishes 8.  Final parameters must match bit-for-bit."""
    cfg = _tiny_cfg()

    def trainer(ckpt_dir, fail_at=None):
        return Trainer(
            cfg,
            TrainerConfig(steps=8, ckpt_every=2, ckpt_dir=ckpt_dir, keep=5,
                          async_ckpt=False, fail_at_step=fail_at, log_every=100),
            seq_len=32, global_batch=4,
        )

    out_a = trainer(str(tmp_path / "a")).run()

    with pytest.raises(RuntimeError, match="injected failure"):
        trainer(str(tmp_path / "b"), fail_at=6).run()
    out_b = trainer(str(tmp_path / "b")).run()   # auto-resumes from step 6

    pa = out_a["state"]["params"]
    pb = out_b["state"]["params"]
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x), np.asarray(y)),
        pa, pb,
    )
    assert int(out_a["state"]["step"]) == int(out_b["state"]["step"]) == 8


def test_loss_decreases_and_straggler_counter(tmp_path):
    cfg = _tiny_cfg()
    tr = Trainer(
        cfg,
        TrainerConfig(steps=12, ckpt_every=100, ckpt_dir=str(tmp_path / "c"),
                      async_ckpt=False, log_every=100),
        seq_len=32, global_batch=4,
    )
    out = tr.run()
    losses = [m["loss"] for m in out["metrics"]]
    assert losses[-1] < losses[0]
    assert all("stragglers" in m and "step_time_s" in m for m in out["metrics"])
