"""The serving engine's contracts (repro.serving).

Four load-bearing properties:

1. **Allocator invariants** — the free-list block allocator never hands out
   the null block, never double-allocates, is all-or-nothing, and raises on
   double-free (property-tested via the hypothesis shim).
2. **Row independence** — a greedy request's output is bit-identical whether
   it runs alone or packed with arbitrary batch-mates, across every model
   family (GQA, MLA, pure-SSM, hybrid).  This is THE correctness property of
   continuous batching: admission order must not change anyone's tokens.
3. **int8 paged KV** — logits match the bf16 paged path within the
   quantization error bound, and a fixed byte budget holds strictly more
   int8 blocks (and concurrent sequences) than bf16.
4. **Compatibility** — the legacy ``Server`` wrapper reproduces direct
   engine results; the wave baseline still serves; ``dip_tp`` sharded
   serving works end-to-end on forced host devices.
"""

import numpy as np
import pytest

from _hypothesis_shim import given, settings, st
from conftest import run_forced_devices as _run

import jax

from repro.configs import get_config
from repro.models import transformer as tf_model
from repro.runtime.server import Request, Server, ServerConfig, WaveServer
from repro.serving import (
    BlockAllocator, Engine, EngineConfig, PagedKVCache, SamplingParams,
    blocks_for_budget, bytes_per_block, max_concurrent,
)
from repro.serving import sampling

# Every zoo config: the four layout families (GQA, MLA+MoE, pure-SSM,
# hybrid) plus the previously-untested members — packed-vs-solo equivalence
# is the fleet's correctness floor, so the whole zoo rides through it.
from repro.configs import ALL_ARCHS as FAMILIES


def _params(cfg, seed=0):
    return tf_model.init_params(jax.random.PRNGKey(seed), cfg)


def _prompts(cfg, n, rng=None, lo=3, hi=10):
    rng = rng or np.random.default_rng(0)
    return [rng.integers(2, cfg.vocab_size, size=int(rng.integers(lo, hi)))
            .astype(np.int32) for _ in range(n)]


# ------------------------------------------------------------- allocator ----
@settings(max_examples=25)
@given(num_blocks=st.integers(min_value=2, max_value=24),
       seed=st.integers(min_value=0, max_value=10_000))
def test_allocator_invariants(num_blocks, seed):
    """Random alloc/free interleavings: no null block, no duplicates,
    all-or-nothing allocation, exact conservation of the block population."""
    rng = np.random.default_rng(seed)
    alloc = BlockAllocator(num_blocks)
    live = []
    for _ in range(40):
        if live and rng.integers(2):
            alloc.free(live.pop(int(rng.integers(len(live)))))
        else:
            n = int(rng.integers(0, num_blocks))
            free_before = alloc.num_free
            got = alloc.alloc(n)
            # all-or-nothing: refuses iff infeasible, never hands out a slice
            if got is None:
                assert n > free_before
                continue
            assert n <= free_before
            assert len(got) == n and BlockAllocator.NULL_BLOCK not in got
            live.append(got)
        flat = [b for blks in live for b in blks]
        assert len(flat) == len(set(flat)), "block double-allocated"
        assert alloc.num_free + len(flat) == num_blocks - 1, "blocks leaked"
    for blks in live:
        alloc.free(blks)
    assert alloc.num_free == num_blocks - 1


def test_allocator_double_free_raises():
    alloc = BlockAllocator(4)
    got = alloc.alloc(2)
    alloc.free(got)
    with pytest.raises(ValueError, match="not currently allocated"):
        alloc.free(got)
    with pytest.raises(ValueError, match="not currently allocated"):
        alloc.free([BlockAllocator.NULL_BLOCK])


def test_block_table_growth_and_release():
    cfg = get_config("llama3_8b").reduced()
    kv = PagedKVCache(cfg, num_blocks=9, block_size=4, slots=2, max_seq=16)
    assert kv.ensure(0, 5)                       # 2 blocks
    assert list(kv.block_tables[0][:2]) != [0, 0]
    assert kv.ensure(0, 8) and len(kv.owned[0]) == 2   # still 2 blocks
    assert kv.ensure(0, 9) and len(kv.owned[0]) == 3
    with pytest.raises(ValueError, match="blocks_per_seq"):
        kv.ensure(0, 17)                         # beyond max_seq
    assert kv.ensure(1, 16)                      # 4 more; 1 usable block left
    kv.release(0)                                # slot 0's 3 blocks return
    assert (kv.block_tables[0] == 0).all() and kv.owned[0] == []
    assert kv.allocator.num_free == 4
    assert kv.ensure(0, 16)                      # exactly refills the pool
    assert not kv.can_allocate(1)                # exhausted -> engine preempts


# --------------------------------------------------------------- sampler ----
def test_sampler_greedy_topk_topp():
    rng = np.random.default_rng(3)
    logits = rng.normal(size=(4, 64)).astype(np.float32)
    u = rng.random((4, 64))
    greedy = sampling.sample_tokens(
        logits, temperature=np.zeros(4, np.float32),
        top_k=np.zeros(4, np.int64), top_p=np.ones(4, np.float32), uniforms=u)
    assert (greedy == logits.argmax(-1)).all()
    # top-k=1 at any temperature is argmax too
    k1 = sampling.sample_tokens(
        logits, temperature=np.full(4, 1.5, np.float32),
        top_k=np.ones(4, np.int64), top_p=np.ones(4, np.float32), uniforms=u)
    assert (k1 == logits.argmax(-1)).all()
    # top-k=8: every draw lands inside each row's top-8 set
    for trial in range(20):
        u = rng.random((4, 64))
        drawn = sampling.sample_tokens(
            logits, temperature=np.full(4, 1.0, np.float32),
            top_k=np.full(4, 8, np.int64), top_p=np.ones(4, np.float32),
            uniforms=u)
        for row, tok in enumerate(drawn):
            assert tok in set(np.argsort(logits[row])[-8:])
    # tiny top-p: nucleus collapses to the argmax
    peaked = np.zeros((2, 16), np.float32)
    peaked[:, 5] = 10.0
    tp = sampling.sample_tokens(
        peaked, temperature=np.ones(2, np.float32),
        top_k=np.zeros(2, np.int64), top_p=np.full(2, 0.1, np.float32),
        uniforms=rng.random((2, 16)))
    assert (tp == 5).all()


def test_seeded_sampling_is_packing_invariant():
    """temperature>0 outputs depend only on the request's seed, not on which
    batch-mates it shares the pool with."""
    cfg = get_config("llama3_8b").reduced()
    params = _params(cfg)
    prompts = _prompts(cfg, 3)
    sp = [SamplingParams(temperature=0.9, top_k=8, max_new_tokens=5, seed=i)
          for i in range(3)]

    eng = Engine(cfg, params, engine_cfg=EngineConfig(
        slots=3, max_seq=32, prefill_chunk=8))
    for i, p in enumerate(prompts):
        eng.add_request(p, sp[i], rid=i)
    packed = eng.run()

    for i, p in enumerate(prompts):
        solo = Engine(cfg, params, engine_cfg=EngineConfig(
            slots=1, max_seq=32, prefill_chunk=8))
        solo.add_request(p, sp[i], rid=0)
        assert solo.run()[0] == packed[i], f"request {i} depends on packing"


# -------------------------------------------------- continuous batching -----
@pytest.mark.parametrize("arch", FAMILIES)
def test_continuous_greedy_matches_solo(arch):
    """Greedy decode is bit-identical packed vs alone for every family —
    including per-slot SSM/hybrid state (mamba2/zamba2)."""
    cfg = get_config(arch).reduced()
    params = _params(cfg)
    prompts = _prompts(cfg, 4)
    sp = SamplingParams(max_new_tokens=6)

    eng = Engine(cfg, params, engine_cfg=EngineConfig(
        slots=3, max_seq=32, prefill_chunk=8))   # 4 requests > 3 slots
    for i, p in enumerate(prompts):
        eng.add_request(p, sp, rid=i)
    packed = eng.run()
    assert set(packed) == set(range(4))

    for i, p in enumerate(prompts):
        solo = Engine(cfg, params, engine_cfg=EngineConfig(
            slots=1, max_seq=32, prefill_chunk=8))
        solo.add_request(p, sp, rid=0)
        assert solo.run()[0] == packed[i], f"{arch} request {i} differs packed"


def test_preemption_recovers_greedy_outputs():
    """A starved pool forces mid-decode evictions; re-prefill on re-admission
    must reproduce the unpressured outputs exactly."""
    cfg = get_config("llama3_8b").reduced()
    params = _params(cfg)
    prompts = _prompts(cfg, 3, lo=6, hi=10)
    sp = SamplingParams(max_new_tokens=8)

    roomy = Engine(cfg, params, engine_cfg=EngineConfig(
        slots=3, max_seq=32, prefill_chunk=8))
    for i, p in enumerate(prompts):
        roomy.add_request(p, sp, rid=i)
    want = roomy.run()

    evicted = []
    tight = Engine(cfg, params, engine_cfg=EngineConfig(
        slots=3, max_seq=32, prefill_chunk=8, block_size=4, num_blocks=11),
        on_preempt=lambda r: evicted.append(r.rid))
    for i, p in enumerate(prompts):
        tight.add_request(p, sp, rid=i)
    got = tight.run()
    assert tight.last_stats["preemptions"] >= 1 and evicted
    assert got == want


def test_streaming_callback_and_stats():
    cfg = get_config("llama3_8b").reduced()
    eng = Engine(cfg, _params(cfg), engine_cfg=EngineConfig(
        slots=2, max_seq=32, prefill_chunk=8))
    seen = []
    eng.add_request(np.arange(2, 7, dtype=np.int32),
                    SamplingParams(max_new_tokens=4), rid=7,
                    on_token=lambda rid, tok, done: seen.append((rid, tok, done)))
    results = eng.run()
    assert [t for _, t, _ in seen] == results[7]
    assert seen[-1][2] and not any(d for _, _, d in seen[:-1])
    st7 = eng.request_stats[7]
    assert st7["prompt_len"] == 5 and st7["new_tokens"] == len(results[7])
    assert st7["ttft_s"] is not None and st7["latency_s"] >= st7["ttft_s"]
    assert eng.last_stats["requests"] == 1


def test_add_request_validation():
    cfg = get_config("llama3_8b").reduced()
    eng = Engine(cfg, _params(cfg), engine_cfg=EngineConfig(slots=1, max_seq=16))
    with pytest.raises(ValueError, match="empty"):
        eng.add_request(np.zeros(0, np.int32))
    with pytest.raises(ValueError, match="no room"):
        eng.add_request(np.ones(16, np.int32))


# ---------------------------------------------------------------- int8 KV ---
def test_int8_paged_kv_matches_bf16_within_bound():
    """int8 K/V storage: greedy serving still completes and the per-step
    logits stay within the quantization error bound of the bf16 paged path."""
    from repro.api.quant import rows_error_bound  # noqa: F401 (the bound's source)

    cfg = get_config("llama3_8b").reduced()
    params = _params(cfg)
    prompt = _prompts(cfg, 1)[0]
    outs = {}
    for kvq in ("none", "int8"):
        eng = Engine(cfg, params, engine_cfg=EngineConfig(
            slots=1, max_seq=32, prefill_chunk=8, kv_quant=kvq))
        eng.add_request(prompt, SamplingParams(max_new_tokens=6), rid=0)
        logits_trace = []
        orig = eng._decode

        def spy(p, pools, cur, ctx, bt, _orig=orig, _trace=logits_trace):
            logits, pools = _orig(p, pools, cur, ctx, bt)
            _trace.append(np.asarray(logits[0, -1], np.float32))
            return logits, pools

        eng._decode = spy
        outs[kvq] = (eng.run()[0], logits_trace)
    # errors compound over steps only through the (identical-until-divergence)
    # token stream; compare the first decode step, which shares inputs exactly
    err = np.abs(outs["none"][1][0] - outs["int8"][1][0]).max()
    assert err < 0.25, f"int8 KV logits off by {err}"
    assert outs["int8"][0][:1] == outs["none"][0][:1], "first token flipped"


def test_int8_capacity_beats_bf16_at_fixed_bytes():
    for arch in ("llama3_8b", "deepseek_v2_lite_16b", "zamba2_2_7b"):
        cfg = get_config(arch).reduced()
        per_bf16 = bytes_per_block(cfg, 16, "none")
        per_int8 = bytes_per_block(cfg, 16, "int8")
        assert 0 < per_int8 < per_bf16, arch
        budget = 64 * per_bf16
        b16 = blocks_for_budget(cfg, budget, 16, "none")
        i8 = blocks_for_budget(cfg, budget, 16, "int8")
        assert i8 > b16, f"{arch}: int8 fits {i8} <= bf16 {b16}"
        assert (max_concurrent(cfg, i8, 64, 16)
                > max_concurrent(cfg, b16, 64, 16)), arch


def test_pure_ssm_has_no_paged_bytes():
    cfg = get_config("mamba2_370m").reduced()
    assert bytes_per_block(cfg, 16, "none") == 0
    with pytest.raises(ValueError, match="no paged KV bytes"):
        blocks_for_budget(cfg, 1 << 20, 16, "none")


# ----------------------------------------------------------- compat layer ---
def test_server_wrapper_matches_engine():
    cfg = get_config("llama3_8b").reduced()
    params = _params(cfg)
    prompts = _prompts(cfg, 3)
    scfg = ServerConfig(batch_slots=2, max_seq=32, max_new_tokens=5,
                        temperature=0.0, top_k=0, prefill_chunk=8)
    srv = Server(cfg, scfg, params)
    reqs = [Request(rid=i, prompt=p) for i, p in enumerate(prompts)]
    via_server = srv.serve(reqs)
    assert all(r.done and r.out_tokens == via_server[r.rid] for r in reqs)
    assert srv.last_stats["requests"] == 3

    eng = Engine(cfg, params, engine_cfg=EngineConfig(
        slots=2, max_seq=32, prefill_chunk=8))
    for i, p in enumerate(prompts):
        eng.add_request(p, SamplingParams(max_new_tokens=5, seed=i), rid=i)
    assert eng.run() == via_server


def test_wave_server_still_serves_with_per_request_caps():
    cfg = get_config("llama3_8b").reduced()
    scfg = ServerConfig(batch_slots=2, max_seq=32, max_new_tokens=8,
                        temperature=0.0, top_k=0)
    ws = WaveServer(cfg, scfg, _params(cfg))
    reqs = [Request(rid=0, prompt=np.arange(2, 6, dtype=np.int32), max_new=3),
            Request(rid=1, prompt=np.arange(2, 9, dtype=np.int32))]
    results = ws.serve(reqs)
    assert len(results[0]) == 3                  # per-request cap honored
    assert len(results[1]) <= 8
    assert ws.last_stats["decode_steps"] > 0


def test_engine_sharded_backend_requires_plan():
    import dataclasses
    cfg = dataclasses.replace(get_config("llama3_8b").reduced(),
                              matmul_backend="dip_tp")
    with pytest.raises(ValueError, match="ShardingPlan"):
        Engine(cfg, engine_cfg=EngineConfig(slots=1, max_seq=16))


def test_dip_tp_sharded_serving_smoke():
    """End-to-end paged serving over a 2-way model mesh: KV-head pools shard
    over 'model', block tables stay host-side, outputs match unsharded."""
    _run("""
import dataclasses
from repro.configs import get_config
from repro.distributed.plan import make_local_mesh, make_plan
from repro.models import transformer as tf_model
from repro.serving import Engine, EngineConfig, SamplingParams

cfg = get_config("llama3_8b").reduced()
params = tf_model.init_params(jax.random.PRNGKey(0), cfg)
prompt = np.arange(2, 9, dtype=np.int32)
sp = SamplingParams(max_new_tokens=4)

ref = Engine(cfg, params, engine_cfg=EngineConfig(slots=2, max_seq=32,
                                                  prefill_chunk=8))
ref.add_request(prompt, sp, rid=0)
want = ref.run()[0]

tp_cfg = dataclasses.replace(cfg, sharding="tp", matmul_backend="dip_tp",
                             compute_dtype="float32")
mesh = make_local_mesh(data=1, model=2)
plan = make_plan(mesh, tp_cfg, "decode")
eng = Engine(tp_cfg, params, engine_cfg=EngineConfig(slots=2, max_seq=32,
                                                     prefill_chunk=8),
             plan=plan)
eng.add_request(prompt, sp, rid=0)
got = eng.run()[0]
assert len(got) == len(want) == 4, (got, want)
assert got == want, f"sharded serving diverged: {got} vs {want}"
print("SHARDED_SERVE_OK")
""", devices=2)


def test_gumbel_boundary_uniform_stays_finite():
    """Regression (pre-PR bug): the upper clip was ``1.0 - 1e-20``, which IS
    1.0 in float64 — a boundary uniform of exactly 1.0 produced +inf Gumbel
    noise that hijacked the argmax (and turned a top-k-masked lane into
    inf + -inf = nan).  The clip must land strictly below 1.0."""
    g = sampling.gumbel_from_uniform(np.array([0.0, 0.5, 1.0, np.nextafter(1.0, 2.0)]))
    assert np.isfinite(g).all(), g

    # end-to-end: one row fed u==1.0 everywhere must still draw from its
    # top-k set, never a masked lane, never token 0 by nan-argmax accident
    logits = np.zeros((1, 16), np.float32)
    logits[0, :4] = 10.0  # only tokens 0-3 are plausible
    tok = sampling.sample_tokens(
        logits, temperature=np.ones(1, np.float32),
        top_k=np.full(1, 4, np.int64), top_p=np.ones(1, np.float32),
        uniforms=np.ones((1, 16)))
    assert int(tok[0]) in range(4)
