"""Autotuner + tuning persistence: clamp edges, candidate generation, cache
round-trips, config shape extraction, and the CLI (with a stubbed measurer —
no device timing in the suite)."""

import dataclasses
import json

import jax.numpy as jnp
import pytest

from repro import api
from repro.api import autotune, tuning
from repro.configs import get_config, matmul_shapes


@pytest.fixture
def clean_table():
    """Snapshot/restore the global tuning table around a test.  The yielded
    snapshot lets a test reset to the pre-test table mid-flight (simulating a
    fresh process)."""
    saved = list(tuning._TABLE)
    yield saved
    tuning._TABLE[:] = saved


@pytest.fixture
def fake_measure(monkeypatch):
    """Replace device timing with a deterministic cost model: the candidate
    with the smallest block volume 'wins'.  Records every call."""
    calls = []

    def fake(backend, x, w, blocks, **kwargs):
        calls.append((backend, blocks))
        bm, bn, bk = blocks
        return float(bm * bn * bk) / 1000.0

    monkeypatch.setattr(autotune, "measure_candidate", fake)
    return calls


def _expected_winner(cands):
    return min(cands, key=lambda b: b.block_m * b.block_n * b.block_k)


# ------------------------------------------------------------ clamp edges ---
def test_clamp_blocks_tiny_m_keeps_sublane_floor():
    assert api.clamp_blocks(api.BlockConfig(256, 256, 256), 1, 64, 64) == (8, 64, 64)
    assert api.clamp_blocks(api.BlockConfig(256, 256, 256), 7, 64, 64) == (8, 64, 64)


def test_clamp_blocks_tiny_k_n_keep_perm_tile_floor():
    assert api.clamp_blocks(api.BlockConfig(128, 256, 256), 128, 1, 1) == (128, 64, 64)


def test_clamp_blocks_rounds_unaligned_entries_up_to_perm_tile():
    # a hand-written (or corrupted-cache) entry that is not a multiple of the
    # 64-wide permutation tile must not poison dispatch
    assert api.clamp_blocks(api.BlockConfig(96, 96, 96), 1024, 1024, 1024) == (96, 128, 128)
    assert api.clamp_blocks(api.BlockConfig(40, 100, 70), 1024, 1024, 1024) == (40, 128, 128)


def test_clamp_blocks_shrinks_to_padded_problem():
    # ragged problem: blocks never exceed the pow2-padded dimension
    assert api.clamp_blocks(api.BlockConfig(512, 512, 512), 100, 130, 200) == (128, 256, 256)


# --------------------------------------------------- exact-shape matching ---
def test_register_measured_entry_is_exact_shape(clean_table):
    tuning.register_measured(
        (8, 128, 64), backend="pallas_dip", dtype="float32",
        m=16, k=128, n=128, persist=False,
    )
    assert tuple(api.lookup_blocks("pallas_dip", 16, 128, 128, jnp.float32)) == (8, 128, 64)
    # neither smaller nor larger problems inherit the measured entry
    assert tuple(api.lookup_blocks("pallas_dip", 8, 128, 128, jnp.float32)) == (8, 128, 128)
    assert tuple(api.lookup_blocks("pallas_dip", 32, 128, 128, jnp.float32)) == (32, 128, 128)
    # nor other dtypes or backends
    assert api.lookup_blocks("pallas_dip", 16, 128, 128, jnp.bfloat16).block_k != 64
    assert tuple(api.lookup_blocks("ws", 16, 128, 128, jnp.float32)) == (16, 128, 128)


# ------------------------------------------------------------- candidates ---
def test_candidate_blocks_are_aligned_and_budgeted():
    cands = autotune.candidate_blocks("pallas_dip", jnp.float32, 128, 256, 256)
    assert len(cands) >= 2
    assert len(set(cands)) == len(cands)
    budget = int(autotune.VMEM_BYTES * autotune.DEFAULT_VMEM_FRACTION)
    incumbent = tuning.lookup_blocks("pallas_dip", 128, 256, 256, jnp.float32)
    assert cands[0] == incumbent
    for c in cands:
        assert c.block_n % api.PERM_TILE == 0 and c.block_k % api.PERM_TILE == 0
        assert c.block_m >= 8
        if c != incumbent:
            assert autotune.estimate_vmem_bytes(c, jnp.float32) <= budget


def test_candidate_blocks_tiny_budget_keeps_only_incumbent():
    cands = autotune.candidate_blocks(
        "pallas_dip", jnp.float32, 128, 256, 256, vmem_budget=1
    )
    assert cands == [tuning.lookup_blocks("pallas_dip", 128, 256, 256, jnp.float32)]


def test_candidate_blocks_systolic_pins_kn_to_array_dim():
    cands = autotune.candidate_blocks("pallas_systolic", jnp.float32, 256, 256, 256)
    assert len(cands) >= 2
    for c in cands:
        assert (c.block_n, c.block_k) == (api.PERM_TILE, api.PERM_TILE)


def test_candidate_cap_respects_limit_and_keeps_incumbent():
    cands = autotune.candidate_blocks(
        "pallas_dip", jnp.float32, 512, 512, 512, max_candidates=3
    )
    assert len(cands) == 3
    assert cands[0] == tuning.lookup_blocks("pallas_dip", 512, 512, 512, jnp.float32)


def test_estimate_vmem_scales_with_dtype_width():
    blocks = api.BlockConfig(128, 128, 128)
    f32 = autotune.estimate_vmem_bytes(blocks, jnp.float32)
    bf16 = autotune.estimate_vmem_bytes(blocks, jnp.bfloat16)
    assert f32 > bf16 > 0


def test_autotune_rejects_non_tiled_backend():
    with pytest.raises(ValueError, match="not tiled"):
        autotune.autotune_shape("xla", 64, 64, 64)


# -------------------------------------------------------- cache roundtrip ---
def test_cache_roundtrip_fresh_load_hits_measured_entry(
    tmp_path, clean_table, fake_measure
):
    """write (autotune) -> fresh load -> lookup_blocks returns the winner."""
    cache = tmp_path / "tuning-test.json"
    res = autotune.autotune_shape(
        "pallas_dip", 64, 128, 128, "float32",
        register=True, persist=True, cache_path=cache,
    )
    assert len(res.measurements) >= 2
    winner = _expected_winner([m.blocks for m in res.measurements])
    assert res.best.blocks == winner

    # simulate a fresh process: restore the pre-test table, reload the cache
    tuning._TABLE[:] = clean_table
    assert tuple(api.lookup_blocks("pallas_dip", 64, 128, 128, jnp.float32)) != tuple(winner)
    assert tuning.load_cache(cache) == 1
    assert api.lookup_blocks("pallas_dip", 64, 128, 128, jnp.float32) == winner
    # the measured entry is exact-shape: a different problem is untouched
    assert tuple(api.lookup_blocks("pallas_dip", 256, 256, 256, jnp.float32)) == (256, 256, 256)


def test_autotune_unaligned_shape_keys_entry_on_padded_dims(
    clean_table, fake_measure
):
    """dip-layout dispatch resolves blocks with the PADDED storage dims, so a
    winner measured for an unaligned problem must be keyed there to ever hit."""
    res = autotune.autotune_shape(
        "pallas_dip", 64, 100, 200, "float32", register=True, persist=False,
    )
    entry = tuning._TABLE[0]
    assert entry.source == "measured"
    assert (entry.min_k, entry.max_k, entry.min_n, entry.max_n) == (128, 128, 256, 256)
    # what registry._tiled_dispatch will actually ask for (storage 128x256)
    assert api.lookup_blocks("pallas_dip", 64, 128, 256, jnp.float32) == res.best.blocks


def test_load_cache_splices_behind_user_registered_rules(clean_table, tmp_path):
    cache = tmp_path / "c.json"
    tuning.save_cache_record(
        dict(backend="pallas_dip", dtype="float32", m=64, k=128, n=128,
             block_m=8, block_n=64, block_k=64),
        cache,
    )
    # deliberately no source= kwarg: the public-API default must stay ahead
    api.register_tuning(
        (64, 128, 128), backend="pallas_dip", dtype="float32",
        max_m=64, min_m=64, max_k=128, min_k=128, max_n=128, min_n=128,
    )
    tuning.load_cache(cache)
    # the explicitly registered rule outranks the cached winner
    assert tuple(api.lookup_blocks("pallas_dip", 64, 128, 128, jnp.float32)) == (64, 128, 128)


def test_save_cache_record_self_heals_corrupt_file(tmp_path):
    cache = tmp_path / "c.json"
    cache.write_text("{this is not json")
    with pytest.warns(UserWarning, match="unreadable"):
        tuning.save_cache_record(
            dict(backend="ws", dtype="float32", m=8, k=64, n=64,
                 block_m=8, block_n=64, block_k=64),
            cache,
        )
    payload = json.loads(cache.read_text())
    assert len(payload["entries"]) == 1


def test_candidate_budget_counts_int32_output_for_int8():
    # int8 operands emit int32: the same geometry costs more VMEM than f32
    blocks = api.BlockConfig(256, 256, 256)
    i8 = autotune.estimate_vmem_bytes(blocks, jnp.int8, jnp.int32)
    f32 = autotune.estimate_vmem_bytes(blocks, jnp.float32)
    assert i8 < f32  # operands shrink 4x but the output stays int32-wide
    assert i8 > autotune.estimate_vmem_bytes(blocks, jnp.int8)
    budget = autotune.estimate_vmem_bytes(blocks, jnp.int8, jnp.int32) - 1
    cands = autotune.candidate_blocks(
        "pallas_dip", jnp.int8, 1024, 1024, 1024, vmem_budget=budget
    )
    assert blocks not in cands[1:]  # filtered at the int32-aware estimate


def test_save_cache_record_replaces_same_key(tmp_path):
    cache = tmp_path / "t.json"
    rec = dict(backend="ws", dtype="float32", m=8, k=64, n=64,
               block_m=8, block_n=64, block_k=64)
    tuning.save_cache_record(rec, cache)
    tuning.save_cache_record(dict(rec, block_m=16), cache)
    payload = json.loads(cache.read_text())
    assert payload["version"] == tuning.CACHE_VERSION
    assert len(payload["entries"]) == 1
    assert payload["entries"][0]["block_m"] == 16


def test_load_cache_rejects_unknown_version(tmp_path):
    cache = tmp_path / "t.json"
    cache.write_text(json.dumps({"version": 999, "entries": []}))
    with pytest.raises(ValueError, match="version"):
        tuning.load_cache(cache)


def test_cache_path_honours_env_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_DIP_CACHE_DIR", str(tmp_path))
    p = tuning.cache_path()
    assert p.parent == tmp_path
    assert p.name.startswith("tuning-") and p.suffix == ".json"


# -------------------------------------------------------------- CLI smoke ---
def test_cli_smoke_with_stubbed_timer(tmp_path, clean_table, fake_measure, capsys):
    cache = tmp_path / "cli.json"
    rc = autotune.main([
        "--backend", "pallas_dip", "--shapes", "32x64x64,32x64x128",
        "--iters", "1", "--cache-path", str(cache),
    ])
    assert rc == 0
    assert len({blocks for _, blocks in fake_measure}) >= 2  # >=2 candidates timed
    payload = json.loads(cache.read_text())
    assert len(payload["entries"]) == 2
    out = capsys.readouterr().out
    assert "best" in out and str(cache) in out

    tuning._TABLE[:] = clean_table
    tuning.load_cache(cache)
    got = api.lookup_blocks("pallas_dip", 32, 64, 64, jnp.float32)
    cands = autotune.candidate_blocks(
        "pallas_dip", jnp.float32, 32, 64, 64, max_candidates=4
    )
    assert got == _expected_winner(cands)


def test_cli_config_shapes_listing(clean_table, fake_measure, tmp_path, capsys):
    rc = autotune.main([
        "--backend", "pallas_dip", "--config", "llama3_8b", "--reduced",
        "--tokens", "32", "--iters", "1", "--max-candidates", "2",
        "--cache-path", str(tmp_path / "cfg.json"),
    ])
    assert rc == 0
    out = capsys.readouterr().out
    assert "distinct projections" in out and "lm_head" in out


# ------------------------------------------------- quantized backends -------
def test_builtin_entries_exist_for_quantized_backends():
    """The table ships K-deepened builtins for dip_int8w/dip_fp8 (the int32
    accumulator already costs full width; operand blocks are narrow)."""
    for backend in ("dip_int8w", "dip_fp8"):
        blocks = api.lookup_blocks(backend, 1024, 1024, 1024, jnp.bfloat16)
        assert blocks.block_k == 512, backend
        # activation-dtype keyed: f32 activations hit the same backend rule
        assert api.lookup_blocks(backend, 1024, 1024, 1024, jnp.float32).block_k == 512


def test_measured_entry_outranks_builtin_for_quantized_backends(clean_table):
    """Precedence: a measured exact-shape entry must beat the builtin rule
    for its (backend, dtype, shape) and ONLY that key."""
    before = api.lookup_blocks("dip_int8w", 64, 128, 256, jnp.bfloat16)
    assert tuple(before) == (64, 256, 128)  # builtin, clamped to the problem
    tuning.register_measured(
        (8, 64, 64), backend="dip_int8w", dtype="bfloat16",
        m=64, k=128, n=256, persist=False,
    )
    assert tuple(api.lookup_blocks("dip_int8w", 64, 128, 256, jnp.bfloat16)) == (8, 64, 64)
    # other dtype, other quantized backend, other shape: still the builtin
    assert tuple(api.lookup_blocks("dip_int8w", 64, 128, 256, jnp.float32)) == (64, 256, 128)
    assert tuple(api.lookup_blocks("dip_fp8", 64, 128, 256, jnp.bfloat16)) == (64, 256, 128)
    assert tuple(api.lookup_blocks("dip_int8w", 32, 128, 256, jnp.bfloat16)) == (32, 256, 128)


@pytest.mark.parametrize("backend,dtype", [("dip_int8w", "bfloat16"), ("dip_fp8", "float32")])
def test_cache_roundtrip_quantized_backend_names(
    tmp_path, clean_table, fake_measure, backend, dtype
):
    """write (autotune, dtype-keyed) -> fresh load -> lookup hits the winner
    under the new backend names, keyed on the PADDED storage dims."""
    cache = tmp_path / "tuning-q.json"
    res = autotune.autotune_shape(
        backend, 64, 100, 200, dtype, register=True, persist=True,
        cache_path=cache,
    )
    entry = tuning._TABLE[0]
    assert (entry.source, entry.backend, entry.dtype) == ("measured", backend, dtype)
    assert (entry.min_k, entry.max_k, entry.min_n, entry.max_n) == (128, 128, 256, 256)

    # simulate a fresh process: pre-test table + cache reload
    tuning._TABLE[:] = clean_table
    assert tuning.load_cache(cache) == 1
    got = api.lookup_blocks(backend, 64, 128, 256, jnp.dtype(dtype))
    assert got == res.best.blocks
    # the cached entry is dtype-keyed: the other activation dtype falls back
    other = jnp.float32 if dtype == "bfloat16" else jnp.bfloat16
    assert tuple(api.lookup_blocks(backend, 64, 128, 256, other)) == (64, 256, 128)
    payload = json.loads(cache.read_text())
    assert payload["entries"][0]["backend"] == backend
    assert payload["entries"][0]["dtype"] == dtype


def test_autotune_operands_for_quantized_backends():
    """_operands hands quantized backends exactly what a serving call site
    holds: float activations in the requested dtype + a QuantizedDipWeight
    of the backend's scheme."""
    x, w, eops = autotune._operands("dip_int8w", jnp.bfloat16, 16, 64, 128)
    assert x.dtype == jnp.bfloat16
    assert isinstance(w, api.QuantizedDipWeight) and w.scheme == "int8"
    assert w.storage_shape == (64, 128) and w.dtype == jnp.int8
    assert eops == ()
    x, w, eops = autotune._operands("dip_fp8", jnp.float32, 16, 64, 128)
    assert isinstance(w, api.QuantizedDipWeight) and w.scheme == "fp8_e4m3"
    assert eops == ()
    # dual-weight epilogue: the weight is the (gate, up) pair matmul expects
    x, w, eops = autotune._operands(
        "dip_int8w", jnp.bfloat16, 16, 64, 128, epilogue="swiglu"
    )
    assert isinstance(w, tuple) and len(w) == 2 and eops == ()
    assert all(wi.scheme == "int8" for wi in w)
    x, w, eops = autotune._operands(
        "pallas_dip", jnp.float32, 16, 64, 128, epilogue="residual"
    )
    assert len(eops) == 1 and eops[0].shape == (16, 128)


def test_autotune_shape_quantized_backend_end_to_end(clean_table):
    """Un-stubbed measurement through the real dip_int8w dispatch (interpret
    mode): the whole candidate->measure->register loop must run."""
    res = autotune.autotune_shape(
        "dip_int8w", 16, 64, 64, "float32",
        iters=1, warmup=1, interpret=True, max_candidates=2,
        register=True, persist=False,
    )
    assert len(res.measurements) >= 1
    assert all(m.time_us > 0 for m in res.measurements)
    assert api.lookup_blocks("dip_int8w", 16, 64, 64, jnp.float32) == res.best.blocks


# --------------------------------------------------------- config shapes ----
from repro.configs import ALL_ARCHS


def _template_pairs(template):
    """(d_in, d_out) problems the template materializes: DiP metadata where
    present, else the trailing two dims of any rank>=2 plain weight (the MoE
    router and stacked expert tensors carry no dip meta but are matmuls)."""
    dip, plain = set(), set()

    def walk(node, name=None):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(v, k)
            return
        if len(node) == 4 and node[3] is not None:  # (shape, dtype, fan, dip)
            d_in, d_out, _ = node[3]
            dip.add((d_in, d_out))
        elif len(node[0]) >= 2 and name not in ("embed", "conv_w"):
            plain.add(tuple(node[0][-2:]))

    walk(template)
    return dip, plain


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_matmul_shapes_match_param_template(name):
    """shapes.py and param_template describe the SAME workload matrix, both
    directions, for every zoo config at full dims:

    * every DipWeight the model materializes is covered by the shape
      extractor (else --autotune tunes the wrong problems);
    * every shape the extractor enumerates exists as a template weight
      (else the autotuner/fleet measures problems no model dispatches).
    """
    from repro.models.transformer import param_template

    cfg = dataclasses.replace(get_config(name), matmul_backend="pallas_dip")
    enumerated = {(s.k, s.n) for s in matmul_shapes(cfg, tokens=32)}
    dip, plain = _template_pairs(param_template(cfg))

    missing = {p for p in dip if p not in enumerated}
    assert not missing, f"{name}: template DiP weights absent from shapes: {missing}"
    phantom = {p for p in enumerated if p not in dip | plain}
    assert not phantom, f"{name}: shapes not materialized by template: {phantom}"


def test_matmul_shapes_dedupes_and_validates_tokens():
    cfg = get_config("llama3_8b").reduced()
    shapes = matmul_shapes(cfg, tokens=64)
    assert len({(s.m, s.k, s.n) for s in shapes}) == len(shapes)
    assert all(s.m == 64 for s in shapes)
    with pytest.raises(ValueError, match="tokens"):
        matmul_shapes(cfg, tokens=0)


def test_autotune_for_config_skips_non_tiled_backend(capsys):
    cfg = get_config("llama3_8b").reduced()  # matmul_backend defaults to xla
    assert autotune.autotune_for_config(cfg) == []
    assert "not tiled" in capsys.readouterr().out
